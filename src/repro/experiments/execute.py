"""Run an :class:`ExperimentPlan` and collect canonical per-cell records.

This is the ONE place where "how a cell executes" is decided — every
harness (``repro.experiments.run``, the legacy ``runtime.compare`` and
``workloads.run`` CLIs, benchmarks, examples) funnels through
``execute(plan)``:

  * synthetic/spec problems run through the strategy registry
    (``Strategy.run`` / ``run_batched``), workload problems through
    ``Workload.run`` / ``run_trials`` — with the plan's placement deciding
    whether R realizations run as a host loop (``single``), one vmapped
    program (``vmap``) or ``shard_map``-ped across devices (``sharded``);
  * every cell yields one **canonical record** (see below) plus the raw
    result object for programmatic callers.

Canonical record schema (the union of the three legacy schemas; every
record carries the core keys, workload records add theirs):

  core:      strategy, delay, seed, metric_name, final_metric,
             final_objective, wallclock_s, times, objective, meta
  synthetic: n, p, m, k
  workload:  workload, preset, metric_times, metric, extras
  batched:   trials, summary {mean/p50/p95 wall-clock + finals}
  skipped:   the identifying keys + ``skipped`` (the reason) only
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Any

import numpy as np

from .io import (print_table, write_json, write_metrics_csv,
                 write_summary_csv, write_trace_csv)
from .plan import ExperimentPlan, PlannedCell
from .spec import ExperimentSpec, ObsAxis

__all__ = ["CellOutcome", "ExperimentResult", "execute", "run",
           "resolve_policy", "trials_record", "cell_label"]


def resolve_policy(name: str, m: int, k: int, *, deadline: float = 1.0,
                   beta: float = 2.0):
    """Build an active-set policy from its CLI name + cell shape."""
    from repro.runtime.engine import make_policy
    if name in ("fastest-k", "adversarial"):
        return make_policy(name, k=k)
    if name == "adaptive-k":
        # k acts as the floor; the policy grows the set per the overlap rule
        return make_policy(name, beta=beta, k_min=k)
    if name == "deadline":
        return make_policy(name, deadline=deadline, k_min=max(1, m // 4))
    raise KeyError(f"unknown policy '{name}'")


def trials_record(results: list, *, delay: str, seed: int) -> dict:
    """Aggregate R per-realization workload results into ONE JSON record:
    stacked per-realization traces plus mean/p50/p95 wall-clock and metric
    summaries.  Scalar ``final_metric`` / ``final_objective`` /
    ``wallclock_s`` are across-trial means, so batched records drop into
    every single-trial consumer (summary CSV, tables)."""
    from repro.runtime.strategies import json_safe_meta, summary_stats
    r0 = results[0]
    final_metric = [r.final_metric for r in results]
    final_obj = [r.final_objective for r in results]
    wallclock = [r.wallclock for r in results]
    return {
        "workload": r0.workload, "strategy": r0.strategy,
        "preset": r0.preset, "metric_name": r0.metric_name,
        "delay": delay, "seed": seed, "trials": len(results),
        "final_metric": float(np.mean(final_metric)),
        "final_objective": float(np.mean(final_obj)),
        "wallclock_s": float(np.mean(wallclock)),
        "summary": {"trials": len(results),
                    "wallclock_s": summary_stats(wallclock),
                    "final_metric": summary_stats(final_metric),
                    "final_objective": summary_stats(final_obj)},
        "times": [np.asarray(r.times, dtype=float).tolist()
                  for r in results],
        "objective": [np.asarray(r.objective, dtype=float).tolist()
                      for r in results],
        "metric_times": [np.asarray(r.metric_times, dtype=float).tolist()
                         for r in results],
        "metric": [np.asarray(r.metric, dtype=float).tolist()
                   for r in results],
        "extras": [r.extras for r in results],
        "meta": json_safe_meta(r0.meta),
    }


@dataclasses.dataclass
class CellOutcome:
    """One executed cell: the canonical record plus the raw result object
    (RunResult / TrialsResult / WorkloadRunResult / list of them; None for
    a skipped cell) for callers that need iterates or schedules."""
    cell: PlannedCell
    record: dict
    result: Any = None

    @property
    def skipped(self) -> bool:
        return "skipped" in self.record


@dataclasses.dataclass
class ExperimentResult:
    """Everything ``execute`` produced, with the shared writers attached.
    ``recorder`` is the run's :class:`repro.obs.TraceRecorder` when the
    spec's :class:`ObsAxis` was enabled, else None."""
    plan: ExperimentPlan
    outcomes: list
    recorder: Any = None
    run_id: str | None = None      # run-store id when the run was recorded

    @property
    def spec(self) -> ExperimentSpec:
        return self.plan.spec

    @property
    def records(self) -> list[dict]:
        return [o.record for o in self.outcomes]

    def to_json(self, path: str) -> None:
        write_json(self.records, path)

    def to_csv(self, path: str) -> None:
        write_trace_csv(self.records, path)

    def to_summary_csv(self, path: str) -> None:
        write_summary_csv(self.records, path)

    def print_table(self) -> None:
        print_table(self.records)

    def to_metrics_csv(self, path: str) -> None:
        write_metrics_csv(self.records, path)


def cell_label(cell: PlannedCell) -> str:
    """The stable human-readable id obs events carry for one cell."""
    prefix = (f"{cell.problem.workload}/"
              if cell.kind == "workload" else "")
    return f"{prefix}{cell.resolved_strategy}x{cell.delay}"


def execute(plan: ExperimentPlan, *, record_to=None) -> ExperimentResult:
    """Run every planned cell; never aborts mid-matrix for per-cell
    incompatibilities (those become skip-with-reason records).

    When the spec carries an enabled :class:`ObsAxis`, the whole matrix runs
    under an active :class:`repro.obs.TraceRecorder`: every record gains
    ``host_s``/``compile_s``/``execute_s``/``compiles`` (the CompileWatch
    split) plus an ``obs`` per-cell metrics summary, and ``obs.trace`` /
    ``obs.profile`` write the trace / profiler artifacts.  With the axis
    off (the default) records are bit-identical to pre-obs builds.

    Every run additionally leaves a provenance manifest in the run store
    (``repro.obs.runstore``) — ``record_to`` controls where: ``None`` uses
    the ``REPRO_RUNSTORE``-governed default store, ``False`` skips
    recording (benchmark timing loops), a :class:`RunStore` or path
    records there.  The manifest is a side artifact; the returned records
    are unaffected.
    """
    obs = getattr(plan.spec, "obs", None)
    cell_batch = getattr(plan.spec.placement, "cell_batch", False)
    if obs is None or not obs.enabled:
        caches: dict = {}
        if cell_batch:
            result = ExperimentResult(
                plan=plan, outcomes=_execute_cellbatched(plan, caches))
        else:
            result = ExperimentResult(
                plan=plan,
                outcomes=[_execute_cell(cell, caches)
                          for cell in plan.cells])
    else:
        if cell_batch:
            # per-cell CompileWatch/metrics attribution needs one dispatch
            # per cell; keep the obs contract and run the matrix unbatched
            print("# obs axis enabled: cell batching falls back to "
                  "per-cell execution")
        result = _execute_observed(plan, obs)
    _record_run(result, record_to)
    return result


def _record_run(result: ExperimentResult, record_to) -> None:
    """Write the run-store manifest (best-effort: a full store disk must
    never fail the experiment itself)."""
    if record_to is False:
        return
    from repro.obs.runstore import (RunStore, default_store,
                                    record_experiment)
    if record_to is None:
        store = default_store()
    elif isinstance(record_to, RunStore):
        store = record_to
    else:
        store = RunStore(str(record_to))
    if store is None:
        return
    try:
        result.run_id = record_experiment(result, store=store)
    except Exception as e:                        # noqa: BLE001
        print(f"# runstore: manifest not recorded: {e}")


def _execute_observed(plan: ExperimentPlan, obs: ObsAxis) -> ExperimentResult:
    from repro.obs import (CompileWatch, TraceRecorder, cell_summary,
                           memory_high_water, profile_region)
    rec = TraceRecorder(meta={"cells": len(plan.cells),
                              "trials": plan.spec.trials.trials,
                              "placement": plan.spec.placement.mode})
    caches: dict = {}
    outcomes: list = []
    with rec.activate():
        for cell in plan.cells:
            label = cell_label(cell)
            mark = rec.checkpoint()
            prof = (profile_region(os.path.join(obs.profile,
                                                f"cell{cell.index:03d}"))
                    if obs.profile and cell.skip is None
                    else contextlib.nullcontext())
            with rec.cell(label), prof, CompileWatch() as cw:
                outcome = _execute_cell(cell, caches)
            if not outcome.skipped:
                summary = cell_summary(rec.sources_since(mark))
                if obs.profile:
                    hwm = memory_high_water()
                    if hwm is not None:
                        summary["memory_high_water_bytes"] = int(hwm)
                outcome.record.update(
                    host_s=cw.total_s, compile_s=cw.compile_s,
                    execute_s=cw.execute_s, compiles=cw.compiles,
                    obs=summary)
            outcomes.append(outcome)
    if obs.trace:
        prefix = obs.trace[:-len(".jsonl")] \
            if obs.trace.endswith(".jsonl") else obs.trace
        d = os.path.dirname(prefix)
        if d:
            os.makedirs(d, exist_ok=True)
        rec.to_jsonl(prefix + ".jsonl")
        rec.to_perfetto(prefix + ".perfetto.json")
    return ExperimentResult(plan=plan, outcomes=outcomes, recorder=rec)


def run(spec: ExperimentSpec) -> ExperimentResult:
    """``execute(plan(spec))`` in one call."""
    from .plan import plan as _plan
    return execute(_plan(spec))


# ---------------------------------------------------------------------------
# Cell execution
# ---------------------------------------------------------------------------

def _engine(cell: PlannedCell):
    from repro.runtime.engine import ClusterEngine, make_delay_model
    return ClusterEngine(make_delay_model(cell.delay), cell.m,
                         compute_time=cell.compute_time, seed=cell.seed)


def _execute_cell(cell: PlannedCell, caches: dict) -> CellOutcome:
    if cell.kind == "workload":
        return _execute_workload_cell(cell, caches)
    return _execute_synthetic_cell(cell, caches)


def _synthetic_problem(cell: PlannedCell, caches: dict):
    from repro.runtime.strategies import ProblemSpec
    key = ("problem", id(cell.problem))
    if key not in caches:
        pr = cell.problem
        if pr.kind == "spec":
            caches[key] = pr.problem
        else:
            seed = pr.seed if pr.seed is not None else cell.seed
            caches[key] = ProblemSpec.synthetic(
                pr.n, pr.p, noise=pr.noise, lam=pr.lam, h=pr.h, seed=seed)
    return caches[key]


def _execute_synthetic_cell(cell: PlannedCell, caches: dict) -> CellOutcome:
    from repro.runtime.strategies import get_strategy
    spec_ = _synthetic_problem(cell, caches)
    st = cell.strategy
    engine = _engine(cell)
    cfg = st.options_dict()
    if cell.resolved_strategy == "async":
        if st.staleness_bound is not None:
            cfg.setdefault("staleness_bound", st.staleness_bound)
        if st.async_updates is not None:
            cfg.setdefault("updates", st.async_updates)
    else:
        if cell.resolved_strategy.startswith("coded"):
            cfg.setdefault("encoder", st.encoder if st.encoder is not None
                           else "hadamard")
        cfg.setdefault("policy", resolve_policy(
            st.policy or "fastest-k", cell.m, cell.k,
            deadline=st.deadline, beta=st.policy_beta))
    base = {"strategy": cell.resolved_strategy, "delay": cell.delay,
            "n": spec_.n, "p": spec_.p, "m": cell.m, "k": cell.k,
            "seed": cell.seed}
    try:
        if cell.trials > 1:
            result = get_strategy(cell.resolved_strategy).run_batched(
                spec_, engine, steps=cell.steps, trials=cell.trials,
                eval_every=cell.eval_every, placement=cell.placement, **cfg)
        else:
            result = get_strategy(cell.resolved_strategy).run(
                spec_, engine, steps=cell.steps, **cfg)
    except ValueError as e:
        print(f"# skipping {cell.resolved_strategy} x {cell.delay}: {e}")
        return CellOutcome(cell, {**base, "skipped": str(e),
                                  "metric_name": "objective"})
    rec = result.to_record()
    rec.update(base, metric_name="objective",
               final_metric=rec["final_objective"])
    return CellOutcome(cell, rec, result)


# ---------------------------------------------------------------------------
# Cell batching: compatible cells -> one compiled program (DESIGN.md §12)
# ---------------------------------------------------------------------------

# strategies whose hot path is the batched_scan_gd/prox runner — the only
# ones where stacking cells along the realization axis is a pure reshape
_CELLBATCH_STRATEGIES = ("coded-gd", "coded-prox", "uncoded", "replication")


def _freeze(v):
    try:
        hash(v)
    except TypeError:
        return id(v)
    return v


def _cellbatch_key(cell: PlannedCell):
    """Group key for one cell, or None when the cell must run on its own.

    Cells in one group share the compiled program, so everything that
    shapes or re-parameterizes it is in the key: problem identity, strategy,
    encoder config, m, steps, trials, eval_every, seed, extra options.
    Delay model / compute time / policy / k / step size are FREE axes —
    they only change the sampled schedules and the per-realization step
    vector.
    """
    if (cell.kind == "workload" or cell.skip is not None
            or cell.placement != "vmap"
            or cell.resolved_strategy not in _CELLBATCH_STRATEGIES):
        return None
    st = cell.strategy
    opts = tuple(sorted((k, _freeze(v)) for k, v in st.options
                        if k != "step_size"))
    return (cell.resolved_strategy, id(cell.problem), cell.m, cell.steps,
            cell.trials, cell.eval_every, cell.seed, _freeze(st.encoder),
            opts)


def _cell_cfg(cell: PlannedCell) -> dict:
    """The per-cell strategy config, exactly as ``_execute_synthetic_cell``
    builds it for the sync-gradient family."""
    st = cell.strategy
    cfg = st.options_dict()
    if cell.resolved_strategy.startswith("coded"):
        cfg.setdefault("encoder", st.encoder if st.encoder is not None
                       else "hadamard")
    cfg.setdefault("policy", resolve_policy(
        st.policy or "fastest-k", cell.m, cell.k,
        deadline=st.deadline, beta=st.policy_beta))
    return cfg


def _execute_cell_group(cells: list, caches: dict) -> list:
    """One compiled program for a group of compatible cells; any
    incompatibility the strategy detects at run time falls back to the
    per-cell path (same records, minus the sharing)."""
    from repro.runtime.strategies import get_strategy
    spec_ = _synthetic_problem(cells[0], caches)
    engines = [_engine(cell) for cell in cells]
    cfgs = [_cell_cfg(cell) for cell in cells]
    strat = get_strategy(cells[0].resolved_strategy)
    try:
        results = strat.run_cellbatched(
            spec_, engines, steps=cells[0].steps, trials=cells[0].trials,
            eval_every=cells[0].eval_every, cfgs=cfgs)
    except ValueError as e:
        print(f"# cell batch of {len(cells)} "
              f"{cells[0].resolved_strategy} cells fell back to per-cell "
              f"execution: {e}")
        return [_execute_cell(cell, caches) for cell in cells]
    outcomes = []
    for cell, result in zip(cells, results):
        base = {"strategy": cell.resolved_strategy, "delay": cell.delay,
                "n": spec_.n, "p": spec_.p, "m": cell.m, "k": cell.k,
                "seed": cell.seed}
        if cell.trials == 1:
            # single-trial cells report the RunResult schema (scalar trace
            # rows), like the unbatched executor; the batching marker stays
            one = result.realization(0)
            for key in ("trials", "eval_every", "batched"):
                one.meta.pop(key, None)
            rec = one.to_record()
            result = one
        else:
            rec = result.to_record()
        rec.update(base, metric_name="objective",
                   final_metric=rec["final_objective"])
        outcomes.append(CellOutcome(cell, rec, result))
    return outcomes


def _execute_cellbatched(plan: ExperimentPlan, caches: dict) -> list:
    """Group compatible cells, run each group as one program, and return
    outcomes in plan order."""
    groups: dict = {}
    for cell in plan.cells:
        groups.setdefault(_cellbatch_key(cell), []).append(cell)
    by_index: dict = {}
    for key, cells in groups.items():
        if key is None or len(cells) == 1:
            for cell in cells:
                by_index[cell.index] = _execute_cell(cell, caches)
        else:
            for cell, oc in zip(cells, _execute_cell_group(cells, caches)):
                by_index[cell.index] = oc
    return [by_index[cell.index] for cell in plan.cells]


def _workload_data(cell: PlannedCell, wl, ps, caches: dict):
    key = ("data", cell.problem.workload, cell.problem.preset)
    if key not in caches:
        caches[key] = wl.build(ps)
    return caches[key]


def _execute_workload_cell(cell: PlannedCell, caches: dict) -> CellOutcome:
    from repro.workloads import UnsupportedStrategy, get_workload
    pr, st = cell.problem, cell.strategy
    wl = get_workload(pr.workload)
    ps = wl.preset(pr.preset)
    base = {"workload": wl.name, "strategy": cell.resolved_strategy,
            "delay": cell.delay, "preset": ps.name, "seed": cell.seed}
    if cell.skip is not None:
        return CellOutcome(cell, {**base, "skipped": cell.skip,
                                  "metric_name": wl.metric_name})
    data = _workload_data(cell, wl, ps, caches)
    engine = _engine(cell)
    cell_cfg = st.options_dict()
    if st.k is not None:
        cell_cfg.setdefault("k", st.k)
    if cell.steps is not None:
        cell_cfg.setdefault("steps", cell.steps)
    if st.encoder is not None:
        cell_cfg.setdefault("encoder", st.encoder)
    if not cell.resolved_strategy.startswith("coded"):
        # encoder targets the coded scheme; uncoded/replication keep their
        # defining encoders.
        cell_cfg.pop("encoder", None)
    # strategy-level config flows into the workload's strategy dispatch the
    # same way it does for synthetic cells — a StrategyAxis field the user
    # set must never be silently dropped
    if cell.resolved_strategy == "async":
        if st.staleness_bound is not None:
            cell_cfg.setdefault("staleness_bound", st.staleness_bound)
        if st.async_updates is not None:
            cell_cfg.setdefault("updates", st.async_updates)
    elif st.policy is not None:
        k = st.k if st.k is not None else ps.k
        cell_cfg.setdefault("policy", resolve_policy(
            st.policy, cell.m, k, deadline=st.deadline,
            beta=st.policy_beta))
    try:
        if cell.trials > 1:
            results = wl.run_trials(st.name, engine, preset=ps, data=data,
                                    trials=cell.trials,
                                    eval_every=cell.eval_every,
                                    placement=cell.placement, **cell_cfg)
            return CellOutcome(
                cell, {**base, **trials_record(results, delay=cell.delay,
                                               seed=cell.seed)}, results)
        result = wl.run(st.name, engine, preset=ps, data=data, **cell_cfg)
    except ValueError as e:
        # UnsupportedStrategy (runtime-detected), or a config clash (e.g.
        # --m below the preset's k) — record the reason, keep the matrix
        # going (same contract as the synthetic path)
        if not isinstance(e, UnsupportedStrategy):
            print(f"# skipping {cell.resolved_strategy} x {cell.delay}: {e}")
        return CellOutcome(cell, {**base, "skipped": str(e),
                                  "metric_name": wl.metric_name})
    rec = result.to_record()
    rec.update(delay=cell.delay, seed=cell.seed)
    return CellOutcome(cell, rec, result)
