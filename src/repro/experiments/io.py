"""Shared experiment record I/O (the ONE copy; DESIGN.md §10).

Every harness record — synthetic compare cells, workload cells, batched
Monte-Carlo cells, skip-with-reason cells — flows through the same three
writers:

  * :func:`write_json`        — the full per-cell records, traces included;
  * :func:`write_trace_csv`   — long format, one row per recorded
    (workload, strategy, delay, trial, step) point;
  * :func:`write_summary_csv` — one row per cell: the paper-table view.

``runtime/compare.py`` and ``workloads/runner.py`` import these instead of
carrying their own copies.
"""
from __future__ import annotations

import csv
import json

__all__ = ["write_json", "trace_rows", "write_trace_csv",
           "write_summary_csv", "print_table"]


def write_json(records: list[dict], path: str) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1)


def trace_rows(rec: dict):
    """Yield (trial, step, time, objective) rows from a record's traces —
    single-trial records carry flat (T,) lists (trial 0), batched records a
    (R, T) nesting."""
    times, obj = rec["times"], rec["objective"]
    if times and isinstance(times[0], (list, tuple)):
        for r, (ts, os_) in enumerate(zip(times, obj)):
            for i, (t, o) in enumerate(zip(ts, os_)):
                yield r, i, t, o
    else:
        for i, (t, o) in enumerate(zip(times, obj)):
            yield 0, i, t, o


def write_trace_csv(records: list[dict], path: str) -> None:
    """Long-format trace table: one row per recorded (strategy, delay,
    trial, step).

    Every row repeats the cell's ``metric_name`` / ``final_metric`` so the
    CSV is self-describing; a skipped cell contributes a single row whose
    ``skipped`` column carries the reason.
    """
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["workload", "strategy", "delay", "trial", "step",
                    "time_s", "objective", "metric_name", "final_metric",
                    "skipped"])
        for rec in records:
            wl = rec.get("workload", "")
            metric_name = rec.get("metric_name", "objective")
            if "skipped" in rec:
                w.writerow([wl, rec["strategy"], rec["delay"], "", "", "",
                            "", metric_name, "", rec["skipped"]])
                continue
            final_metric = f"{rec['final_metric']:.8e}"
            for r, i, t, obj in trace_rows(rec):
                w.writerow([wl, rec["strategy"], rec["delay"], r, i,
                            f"{t:.6f}", f"{obj:.8e}", metric_name,
                            final_metric, ""])


def write_summary_csv(records: list[dict], path: str) -> None:
    """One row per cell: the paper-table view (final metric + wall-clock)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["workload", "strategy", "delay", "preset", "metric_name",
                    "final_metric", "final_objective", "wallclock_s",
                    "skipped"])
        for r in records:
            if "skipped" in r:
                w.writerow([r.get("workload", ""), r["strategy"], r["delay"],
                            r.get("preset", ""), r.get("metric_name", ""),
                            "", "", "", r["skipped"]])
            else:
                w.writerow([r.get("workload", ""), r["strategy"], r["delay"],
                            r.get("preset", ""), r["metric_name"],
                            f"{r['final_metric']:.6g}",
                            f"{r['final_objective']:.6g}",
                            f"{r['wallclock_s']:.2f}", ""])


def print_table(records: list[dict]) -> None:
    """Human summary of a record list on stdout (shared by all CLIs)."""
    has_wl = any(r.get("workload") for r in records)
    head = (f"{'workload':10s} " if has_wl else "") + \
        (f"{'strategy':14s} {'delay':12s} {'final f':>12s} "
         f"{'metric':>22s} {'wallclock_s':>12s} {'trialsxT':>9s}")
    print(head)
    for rec in records:
        lead = f"{rec.get('workload', '-'):10s} " if has_wl else ""
        if "skipped" in rec:
            print(f"{lead}{rec['strategy']:14s} {rec['delay']:12s} "
                  f"{'skipped:':>12s} {rec['skipped']}")
            continue
        metric = f"{rec['metric_name']}={rec['final_metric']:.5g}"
        obj = rec["objective"]
        shape = (f"{len(obj)}x{len(obj[0])}"
                 if obj and isinstance(obj[0], (list, tuple))
                 else f"1x{len(obj)}")
        print(f"{lead}{rec['strategy']:14s} {rec['delay']:12s} "
              f"{rec['final_objective']:12.5f} {metric:>22s} "
              f"{rec['wallclock_s']:12.2f} {shape:>9s}")
