"""Shared experiment record I/O (the ONE copy; DESIGN.md §10).

Every harness record — synthetic compare cells, workload cells, batched
Monte-Carlo cells, skip-with-reason cells — flows through the same three
writers:

  * :func:`write_json`        — the full per-cell records, traces included;
  * :func:`write_trace_csv`   — long format, one row per recorded
    (workload, strategy, delay, trial, step) point;
  * :func:`write_summary_csv` — one row per cell: the paper-table view;
  * :func:`write_metrics_csv` — one row per cell: the obs view (compile /
    execute split, miss-rate, active-set, latency percentiles, staleness),
    from records produced with the spec's :class:`ObsAxis` enabled.

``runtime/compare.py`` and ``workloads/runner.py`` import these instead of
carrying their own copies.
"""
from __future__ import annotations

import csv
import json

__all__ = ["write_json", "trace_rows", "write_trace_csv",
           "write_summary_csv", "write_metrics_csv", "print_table"]


def write_json(records: list[dict], path: str) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1)


def trace_rows(rec: dict):
    """Yield (trial, step, time, objective) rows from a record's traces —
    single-trial records carry flat (T,) lists (trial 0), batched records a
    (R, T) nesting."""
    times, obj = rec["times"], rec["objective"]
    if times and isinstance(times[0], (list, tuple)):
        for r, (ts, os_) in enumerate(zip(times, obj)):
            for i, (t, o) in enumerate(zip(ts, os_)):
                yield r, i, t, o
    else:
        for i, (t, o) in enumerate(zip(times, obj)):
            yield 0, i, t, o


def write_trace_csv(records: list[dict], path: str) -> None:
    """Long-format trace table: one row per recorded (strategy, delay,
    trial, step).

    Every row repeats the cell's ``metric_name`` / ``final_metric`` so the
    CSV is self-describing; a skipped cell contributes a single row whose
    ``skipped`` column carries the reason.
    """
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["workload", "strategy", "delay", "trial", "step",
                    "time_s", "objective", "metric_name", "final_metric",
                    "skipped"])
        for rec in records:
            wl = rec.get("workload", "")
            metric_name = rec.get("metric_name", "objective")
            if "skipped" in rec:
                w.writerow([wl, rec["strategy"], rec["delay"], "", "", "",
                            "", metric_name, "", rec["skipped"]])
                continue
            final_metric = f"{rec['final_metric']:.8e}"
            for r, i, t, obj in trace_rows(rec):
                w.writerow([wl, rec["strategy"], rec["delay"], r, i,
                            f"{t:.6f}", f"{obj:.8e}", metric_name,
                            final_metric, ""])


def write_summary_csv(records: list[dict], path: str) -> None:
    """One row per cell: the paper-table view (final metric + wall-clock)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["workload", "strategy", "delay", "preset", "metric_name",
                    "final_metric", "final_objective", "wallclock_s",
                    "skipped"])
        for r in records:
            if "skipped" in r:
                w.writerow([r.get("workload", ""), r["strategy"], r["delay"],
                            r.get("preset", ""), r.get("metric_name", ""),
                            "", "", "", r["skipped"]])
            else:
                w.writerow([r.get("workload", ""), r["strategy"], r["delay"],
                            r.get("preset", ""), r["metric_name"],
                            f"{r['final_metric']:.6g}",
                            f"{r['final_objective']:.6g}",
                            f"{r['wallclock_s']:.2f}", ""])


METRICS_COLUMNS = [
    "workload", "strategy", "delay", "trials",
    "host_s", "compile_s", "execute_s", "compiles",
    "mean_miss_rate", "max_miss_rate",
    "active_size_mean", "active_size_min", "active_size_max",
    "step_latency_p50", "step_latency_p95", "step_latency_p99",
    "staleness_mean", "staleness_max", "staleness_clamped", "dropped",
    "delay_tail_p99_max", "delay_tail_p99_mean", "delay_tail_p99_workers",
    "crashes", "blackout_s", "corrupt_count", "subk_fraction",
    "skipped",
]


def _fmt(v, spec: str = ".6g") -> str:
    return "" if v is None else format(v, spec)


def write_metrics_csv(records: list[dict], path: str) -> None:
    """One row per cell: the straggler/compile metrics attached by
    ``execute`` under an enabled :class:`ObsAxis` (records without the
    ``obs`` key — e.g. from a no-obs run — produce mostly-empty rows)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(METRICS_COLUMNS)
        pad = len(METRICS_COLUMNS) - 4        # between delay and skipped
        for r in records:
            if "skipped" in r:
                w.writerow([r.get("workload", ""), r["strategy"],
                            r["delay"]] + [""] * pad + [r["skipped"]])
                continue
            obs = r.get("obs", {})
            sched = obs.get("schedule", {})
            asy = obs.get("async", {})
            active = sched.get("active_size", {})
            lat = sched.get("step_latency_s", {})
            stale = asy.get("staleness", {})
            # delay_tail comes from whichever artifact stream the cell
            # produced (sync schedules or the async trace)
            tail = sched.get("delay_tail") or asy.get("delay_tail") or {}
            faults = sched.get("faults", {})
            # subk_fraction lives in the strategy meta (it knows the
            # decode threshold); the obs summarizer has no k
            subk = (r.get("meta") or {}).get("subk_fraction")
            w.writerow([
                r.get("workload", ""), r["strategy"], r["delay"],
                r.get("trials", 1),
                _fmt(r.get("host_s")), _fmt(r.get("compile_s")),
                _fmt(r.get("execute_s")), _fmt(r.get("compiles"), "d"),
                _fmt(sched.get("mean_miss_rate")),
                _fmt(sched.get("max_miss_rate")),
                _fmt(active.get("mean")), _fmt(active.get("min")),
                _fmt(active.get("max")),
                _fmt(lat.get("p50")), _fmt(lat.get("p95")),
                _fmt(lat.get("p99")),
                _fmt(stale.get("mean")), _fmt(stale.get("max")),
                _fmt(asy.get("staleness_clamped"), "d"),
                _fmt(asy.get("dropped"), "d"),
                _fmt(tail.get("p99_max")), _fmt(tail.get("p99_mean")),
                _fmt(tail.get("workers"), "d"),
                _fmt(faults.get("crashes"), "d"),
                _fmt(faults.get("blackout_s")),
                _fmt(faults.get("corrupt_count"), "d"),
                _fmt(subk), "",
            ])


def print_table(records: list[dict]) -> None:
    """Human summary of a record list on stdout (shared by all CLIs).

    Records from an obs-enabled run carry the CompileWatch host-time
    split; the table then grows a ``compile/exec_s`` column so one glance
    separates jit compilation from steady-state execution.
    """
    has_wl = any(r.get("workload") for r in records)
    has_split = any(r.get("compile_s") is not None for r in records)
    head = (f"{'workload':10s} " if has_wl else "") + \
        (f"{'strategy':14s} {'delay':12s} {'final f':>12s} "
         f"{'metric':>22s} {'wallclock_s':>12s}") + \
        (f" {'compile/exec_s':>15s}" if has_split else "") + \
        f" {'trialsxT':>9s}"
    print(head)
    for rec in records:
        lead = f"{rec.get('workload', '-'):10s} " if has_wl else ""
        if "skipped" in rec:
            print(f"{lead}{rec['strategy']:14s} {rec['delay']:12s} "
                  f"{'skipped:':>12s} {rec['skipped']}")
            continue
        metric = f"{rec['metric_name']}={rec['final_metric']:.5g}"
        obj = rec["objective"]
        shape = (f"{len(obj)}x{len(obj[0])}"
                 if obj and isinstance(obj[0], (list, tuple))
                 else f"1x{len(obj)}")
        split = ""
        if has_split:
            cs, es = rec.get("compile_s"), rec.get("execute_s")
            split = (f" {cs:7.2f}/{es:7.2f}"
                     if cs is not None and es is not None
                     else f" {'-':>15s}")
        print(f"{lead}{rec['strategy']:14s} {rec['delay']:12s} "
              f"{rec['final_objective']:12.5f} {metric:>22s} "
              f"{rec['wallclock_s']:12.2f}{split} {shape:>9s}")
