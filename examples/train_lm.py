"""End-to-end driver: train a ~100M-param LM with CODED data parallelism
under simulated stragglers, and compare against the uncoded baseline that
waits for every worker.

Default runs a fast CPU-sized preset; pass --preset 100m for the full-size
run (same code path, ~100M params, a few hundred steps).

  PYTHONPATH=src python examples/train_lm.py                 # ~2 min CPU
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200
"""
import argparse

import numpy as np

from repro.configs import ARCHS
from repro.core.straggler import bimodal_delays
from repro.train.trainer import Trainer, TrainerConfig


def build_cfg(preset: str):
    base = ARCHS["deepseek-7b"]
    if preset == "100m":
        # ~100M params: 12L x 768, vocab 16k, tied embeddings
        return base.with_overrides(
            n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=2048,
            vocab=16384, head_dim=64, dtype="float32",
            param_dtype="float32", attn_chunk=256)
    return base.smoke_variant().with_overrides(vocab=1024)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=["small", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--uncoded-baseline", action="store_true",
                    help="also run the beta=1 wait-for-all baseline")
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    tcfg = TrainerConfig(m_workers=8, beta=2, wait_k=6, rows_per_worker=1,
                         seq_len=args.seq_len, steps=args.steps, lr=3e-3,
                         warmup=10, log_every=10)
    print(f"== coded DP (beta=2, wait k={tcfg.wait_k}/{tcfg.m_workers}) ==")
    tr = Trainer(cfg, tcfg, delay_model=bimodal_delays())
    _, _, hist = tr.run()
    coded_loss = np.mean([h["loss"] for h in hist[-10:]])
    coded_time = hist[-1]["sim_time_s"]
    print(f"coded:   final loss {coded_loss:.4f}, "
          f"simulated wall-clock {coded_time:.0f}s")

    if args.uncoded_baseline:
        print("== uncoded baseline (beta=1, wait for ALL workers) ==")
        tcfg_u = TrainerConfig(m_workers=8, beta=1, wait_k=8,
                               rows_per_worker=1, seq_len=args.seq_len,
                               steps=args.steps, lr=3e-3, warmup=10,
                               log_every=10, uncoded=True)
        tru = Trainer(cfg, tcfg_u, delay_model=bimodal_delays())
        _, _, hist_u = tru.run()
        u_loss = np.mean([h["loss"] for h in hist_u[-10:]])
        u_time = hist_u[-1]["sim_time_s"]
        print(f"uncoded: final loss {u_loss:.4f}, "
              f"simulated wall-clock {u_time:.0f}s")
        print(f"speedup at equal steps: {u_time / coded_time:.2f}x "
              f"(coded skips the stragglers every step)")


if __name__ == "__main__":
    main()
