"""End-to-end driver: train an LM with CODED data parallelism under
simulated stragglers, and compare against an uncoded no-straggler
baseline that waits for every worker.

Both runs go through the spec -> plan -> execute harness (DESIGN §15), so
each gets a canonical record, obs metrics, and — when ``REPRO_RUNSTORE``
is set — a run-store manifest, exactly like ``repro.experiments.run``
cells.  The acceptance bar this script prints is the paper's: at EQUAL
steps, coded SGD under adversarial stragglers should land within 5% of
the uncoded baseline's loss while finishing each step after only the
fastest k arrivals.

  PYTHONPATH=src python examples/train_lm.py                 # ~2 min CPU
  PYTHONPATH=src python examples/train_lm.py --code cyclic --faults preset:ec2-tail
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200
"""
import argparse
import json
import math
import os

import numpy as np

from repro.experiments.execute import execute
from repro.experiments.plan import plan
from repro.experiments.spec import (DelayAxis, ExperimentSpec, ObsAxis,
                                    PlacementAxis, ProblemAxis, StrategyAxis,
                                    TrialsAxis)


def _spec(args, *, strategy, code, delays, policy, k, rows_per_worker,
          faults=None, beta=None):
    """One single-cell train-kind spec; lr/warmup/log_every ride in the
    StrategyAxis options escape hatch (run_coded_sgd kwargs)."""
    options = [("lr", args.lr), ("warmup", args.warmup),
               ("log_every", args.log_every)]
    if code is not None:
        options.append(("code", code))
    if beta is not None:
        options.append(("beta", beta))
    return ExperimentSpec(
        problems=(ProblemAxis.train(args.arch, preset=args.preset,
                                    seq_len=args.seq_len,
                                    rows_per_worker=rows_per_worker),),
        strategies=(StrategyAxis(name=strategy, policy=policy, k=k,
                                 options=tuple(options)),),
        delays=DelayAxis(delays=delays, m=args.m, faults=faults),
        trials=TrialsAxis(trials=1, eval_every=1, seed=args.seed),
        placement=PlacementAxis(mode="single"),
        steps=args.steps, obs=ObsAxis())


def _run(spec) -> dict:
    result = execute(plan(spec))
    rec = result.records[0]
    rec["run_id"] = result.run_id
    return rec


def _tail_loss(rec, steps: int) -> float:
    return float(np.mean(rec["objective"][-min(10, steps):]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--strategy", default="coded-sgd",
                    choices=["coded-sgd", "uncoded"])
    ap.add_argument("--code", default="frc",
                    help="gradient code: frc/cyclic/stochastic/uncoded")
    ap.add_argument("--policy", default="adversarial",
                    choices=["fastest-k", "adaptive-k", "deadline",
                             "adversarial"],
                    help="active-set policy for the straggler run "
                         "(adversarial = rotate the worst-case miss set)")
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--beta", type=int, default=2,
                    help="replication factor of the gradient code")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128, dest="seq_len")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--delays", default="bimodal")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="fault spec or chaos preset ('preset:ec2-tail', "
                         "'preset:zone-outage', ...) for the coded run")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the uncoded no-straggler reference run")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, metavar="JSON",
                    help="write the comparison summary as JSON (CI hook)")
    args = ap.parse_args()

    # FRC with rows_per_worker=beta draws the SAME b*rows = m sequences per
    # step as the uncoded run (b = m/beta clusters), so with an exact decode
    # the two trajectories consume identical tokens and match to FP noise.
    # Non-FRC codes overlap groups across workers, so rows stay at 1.
    rows = args.beta if args.code == "frc" else 1
    options_beta = args.beta if args.strategy == "coded-sgd" else 1

    print(f"== {args.strategy} ({args.code}, beta={options_beta}, "
          f"{args.policy} k={args.k}/{args.m}) on {args.delays}"
          + (f" + faults '{args.faults}'" if args.faults else "") + " ==")
    spec = _spec(args, strategy=args.strategy,
                 code=args.code if args.strategy == "coded-sgd" else None,
                 delays=tuple(s.strip() for s in args.delays.split(",")
                              if s.strip()),
                 policy=args.policy, k=args.k, rows_per_worker=rows,
                 faults=args.faults,
                 beta=args.beta if args.strategy == "coded-sgd" else None)
    coded = _run(spec)
    coded_loss = _tail_loss(coded, args.steps)
    coded_time = float(coded["times"][-1])
    meta = coded["meta"]
    print(f"{args.strategy}: final loss {coded_loss:.4f}, sim wall-clock "
          f"{coded_time:.0f}s, exact decode on "
          f"{meta.get('exact_fraction', 0.0) * 100.0:.0f}% of steps, "
          f"mean active {meta.get('mean_active', args.m):.1f}/{args.m}")

    summary = {"coded": {"strategy": args.strategy, "code": args.code,
                         "loss": coded_loss, "sim_time_s": coded_time,
                         "losses": [float(v) for v in coded["objective"]],
                         "meta": meta, "run_id": coded.get("run_id")}}
    ok = math.isfinite(coded_loss)

    if not args.no_baseline:
        print(f"== uncoded no-straggler baseline (constant delays, "
              f"wait for all {args.m}) ==")
        base_spec = _spec(args, strategy="uncoded", code=None,
                          delays=("constant",), policy="fastest-k",
                          k=args.m, rows_per_worker=1)
        base = _run(base_spec)
        base_loss = _tail_loss(base, args.steps)
        base_time = float(base["times"][-1])
        ratio = coded_loss / base_loss if base_loss else float("inf")
        gap = ratio - 1.0
        verdict = "PASS" if gap <= 0.05 else "WARN"
        ok = ok and math.isfinite(base_loss) and verdict == "PASS"
        print(f"uncoded: final loss {base_loss:.4f}, sim wall-clock "
              f"{base_time:.0f}s")
        print(f"loss ratio coded/uncoded at equal steps: {ratio:.4f} "
              f"({gap:+.2%} vs the 5% acceptance bar) -> {verdict}")
        if coded_time:
            print(f"speedup over waiting for all under stragglers: the "
                  f"coded run finishes each step after the fastest "
                  f"{args.k} arrivals")
        summary["baseline"] = {"loss": base_loss, "sim_time_s": base_time,
                               "losses": [float(v)
                                          for v in base["objective"]],
                               "run_id": base.get("run_id")}
        summary["ratio"] = ratio
        summary["verdict"] = verdict
    summary["ok"] = bool(ok)

    if args.out:
        d = os.path.dirname(args.out)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"wrote summary to {args.out}")
    return summary


if __name__ == "__main__":
    main()
