"""LASSO sparsity recovery under stragglers (paper §5.4, Fig 14):
encoded proximal gradient (ISTA) with Steiner-ETF encoding vs the uncoded
fastest-k baseline, under an ADVERSARIAL erasure schedule.

  PYTHONPATH=src python examples/lasso_recovery.py
"""
import numpy as np

from repro.core import (make_encoder, pad_rows, make_encoded_problem,
                        run_encoded_proximal, adversarial_sets, active_mask)
from repro.data import lsq_dataset


def f1_score(w_hat, w_true, tol=1e-3):
    nz_h, nz_t = np.abs(w_hat) > tol, np.abs(w_true) > 0
    tp = (nz_h & nz_t).sum()
    prec = tp / max(nz_h.sum(), 1)
    rec = tp / max(nz_t.sum(), 1)
    return 2 * prec * rec / max(prec + rec, 1e-9)


m, k, steps = 16, 12, 300
n, p, s = 512, 256, 20
X, y, w_true = lsq_dataset(n, p, noise=0.4, sparse=s, seed=0)
L = float(np.linalg.eigvalsh(X.T @ X / n).max())
masks = np.stack([active_mask(m, A) for A in adversarial_sets(m, k, steps)])

for name in ["uncoded", "replication", "steiner", "hadamard"]:
    enc = pad_rows(make_encoder(
        name, n, beta=1.0 if name == "uncoded" else 2.0), m)
    prob = make_encoded_problem(X, y, enc, m, lam=0.08)
    w, tr = run_encoded_proximal(prob, masks, step_size=0.5 / L)
    print(f"{name:12s} F1={f1_score(np.asarray(w), w_true):.3f} "
          f"final_obj={tr[-1]:.4f}")
