"""LASSO sparsity recovery under stragglers (paper §5.4, Fig 14):
encoded proximal gradient (ISTA) vs the uncoded fastest-k baseline, under
an ADVERSARIAL erasure schedule — through the workloads API, so the
dataset, the FISTA ground truth and the F1 metric are the library's, not
hand-rolled.

  PYTHONPATH=src python examples/lasso_recovery.py
"""
from repro.runtime import AdversarialRotation
from repro.workloads import get_workload

wl = get_workload("lasso")
ps = wl.preset("smoke")
data = wl.build(ps)
engine = wl.default_engine(ps)

print(f"n={ps.dims['n']} p={ps.dims['p']} support={ps.dims['sparse']} "
      f"m={ps.m} adversarial k={ps.k}")
for strategy, encoder in [("uncoded", None), ("replication", None),
                          ("coded-prox", "steiner"),
                          ("coded-prox", "hadamard")]:
    cfg = {"encoder": encoder} if encoder else {}
    res = wl.run(strategy, engine, preset=ps, data=data,
                 policy=AdversarialRotation(ps.k), **cfg)
    label = encoder or strategy
    print(f"{label:12s} F1={res.final_metric:.3f} "
          f"final_obj={res.final_objective:.4f} "
          f"(gap to FISTA f*: {res.meta['final_subopt_gap']:.2e})")
