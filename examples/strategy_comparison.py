"""The paper's headline comparison (§5) through the runtime harness:
encoded vs uncoded vs replication vs asynchronous stale-gradient SGD, under
three delay distributions, measured in SIMULATED WALL-CLOCK (not iterations).

Sync strategies pay the fastest-k barrier per iteration; async pays per
arrival — so async takes many more (stale) steps in the same span of time.
The interesting question the table answers: who reaches a good objective
EARLIEST in wall-clock?

Run:  PYTHONPATH=src python examples/strategy_comparison.py
"""
import numpy as np

from repro.runtime.compare import run_matrix

STRATEGIES = ["coded-gd", "uncoded", "replication", "async"]
DELAYS = ["bimodal", "power_law", "exponential"]

# coded strategies encode with the MATRIX-FREE fast-Hadamard operator
# (fused Pallas FWHT; same ensemble as the dense 'hadamard' encoder, but S
# is never materialized — see DESIGN §7)
records = run_matrix(STRATEGIES, DELAYS, n=512, p=128, m=16, k=12,
                     steps=150, seed=0, encoder="fast-hadamard")

# time (simulated seconds) for each strategy to first reach 1.01x the best
# final objective seen under that delay model
print(f"{'delay':12s} {'strategy':13s} {'final f':>10s} {'wall_s':>9s} "
      f"{'t_to_1%':>9s}")
for delay in DELAYS:
    cell = [r for r in records if r["delay"] == delay]
    target = 1.01 * min(r["final_objective"] for r in cell)
    for r in cell:
        obj = np.asarray(r["objective"])
        hit = np.nonzero(obj <= target)[0]
        t_hit = f"{r['times'][hit[0]]:9.2f}" if hit.size else "      inf"
        print(f"{delay:12s} {r['strategy']:13s} {r['final_objective']:10.4f} "
              f"{r['wallclock_s']:9.2f} {t_hit}")
