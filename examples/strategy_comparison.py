"""The paper's headline comparison (§5) through the experiment API:
the ridge workload (encoded L-BFGS vs uncoded vs replication vs async
stale-gradient SGD) under three delay distributions, measured in SIMULATED
WALL-CLOCK (not iterations) and scored with the workload's paper metric —
suboptimality gap against the closed-form ground truth.  The whole matrix
is ONE declarative ``ExperimentSpec`` (DESIGN.md §10).

Sync strategies pay the fastest-k barrier per iteration; async pays per
arrival — so async takes many more (stale) steps in the same span of time.
The interesting question the table answers: who reaches a small gap
EARLIEST in wall-clock?

Run:  PYTHONPATH=src python examples/strategy_comparison.py
"""
import numpy as np

from repro.experiments import (DelayAxis, ExperimentSpec, ProblemAxis,
                               StrategyAxis, run)

STRATEGIES = ["coded", "uncoded", "replication", "async"]
DELAYS = ["bimodal", "power_law", "exponential"]

spec = ExperimentSpec(
    problems=(ProblemAxis.from_workload("ridge", "smoke"),),
    strategies=tuple(StrategyAxis(s) for s in STRATEGIES),
    delays=DelayAxis(delays=tuple(DELAYS)))
records = run(spec).records

# time (simulated seconds) for each strategy to first push the
# suboptimality gap below 1.1x the best final gap under that delay model
print(f"{'delay':12s} {'strategy':13s} {'final gap':>10s} {'wall_s':>9s} "
      f"{'t_to_best':>10s}")
for delay in DELAYS:
    cell = [r for r in records if r["delay"] == delay and "skipped" not in r]
    target = 1.1 * min(max(r["final_metric"], 1e-12) for r in cell)
    for r in cell:
        gap = np.asarray(r["metric"])
        hit = np.nonzero(gap <= target)[0]
        t_hit = f"{r['metric_times'][hit[0]]:10.2f}" if hit.size \
            else "       inf"
        print(f"{delay:12s} {r['strategy']:13s} {r['final_metric']:10.2e} "
              f"{r['wallclock_s']:9.2f} {t_hit}")
