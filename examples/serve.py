"""Batched serving example: prefill a batch of prompts, then decode tokens
incrementally with the KV/state caches — the same serve path the dry-run
lowers for decode_32k / long_500k.

  PYTHONPATH=src python examples/serve.py --arch gemma2-27b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].smoke_variant()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    kw = {}
    if cfg.n_patches:
        kw["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_vision)) * 0.02,
            jnp.float32)
        kw["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.n_enc_layers:
        kw["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_enc_frames, cfg.d_model)) * 0.02,
            jnp.float32)

    t0 = time.perf_counter()
    logits, caches = jax.jit(
        lambda p, t: model.prefill(p, t, cache_len=S + args.tokens, **kw)
    )(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {B}x{S} in {t_prefill * 1e3:.0f} ms")

    decode = jax.jit(model.decode)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(S + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {args.tokens - 1} steps x batch {B} in {dt * 1e3:.0f} ms"
          f"  ({(args.tokens - 1) * B / max(dt, 1e-9):.1f} tok/s)")
    print("sample continuation token ids:", np.asarray(toks[0][:12]))


if __name__ == "__main__":
    main()
