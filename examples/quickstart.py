"""Quickstart: encoded distributed ridge regression in ~40 lines.

The master waits for the fastest k of m workers every iteration; the
Hadamard encoding makes the fastest-k gradient a faithful estimate of the
full gradient regardless of WHICH workers straggle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (hadamard_encoder, make_encoded_problem,
                        run_encoded_gd, original_objective,
                        bimodal_delays, simulate_run, active_mask)
from repro.data import lsq_dataset

m, k = 16, 12           # 16 workers, wait for the fastest 12
n, p = 512, 128

# 1. data + encoding: workers store S_i X rather than X_i  (beta = 2)
X, y, _ = lsq_dataset(n, p, noise=0.5, seed=0)
enc = hadamard_encoder(n, beta=2.0)
prob = make_encoded_problem(X, y, enc, m, lam=0.05)

# 2. simulate stragglers (bimodal delays from the paper) -> per-step masks
masks = np.stack([active_mask(m, A)
                  for _, A, _ in simulate_run(bimodal_delays(), m, k, 200)])

# 3. run encoded gradient descent, obliviously to the erasures
L = float(np.linalg.eigvalsh(X.T @ X / n).max())
w, trace = run_encoded_gd(prob, masks, step_size=1.0 / (1.3 * L + 0.05))

# 4. compare against the exact ridge solution
w_star = np.linalg.solve(X.T @ X / n + 0.05 * np.eye(p), X.T @ y / n)
f_star = float(original_objective(prob, jnp.asarray(w_star), h="l2"))
print(f"f(w_0)   = {trace[0]:.4f}")
print(f"f(w_T)   = {trace[-1]:.4f}   (encoded, {m - k} stragglers/step)")
print(f"f(w*)    = {f_star:.4f}   (exact optimum)")
print(f"suboptimality: {trace[-1] / f_star - 1:.2%}")
assert trace[-1] < 1.05 * f_star
print("OK: converged within the paper's kappa-ball of the optimum")
