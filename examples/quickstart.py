"""Quickstart: encoded distributed ridge regression via the experiment API.

The master waits for the fastest k of m workers every iteration; the
Hadamard encoding makes the fastest-k gradient a faithful estimate of the
full gradient regardless of WHICH workers straggle.  One declarative
``ExperimentSpec`` names the whole cell — problem, strategy, delay model,
cluster shape — and ``run`` compiles it to a plan and executes it
(DESIGN.md §10); the iteration loop itself is a single device-resident
``lax.scan``.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import identity_encoder, make_encoded_problem, \
    original_objective
from repro.experiments import (DelayAxis, ExperimentSpec, ProblemAxis,
                               StrategyAxis, run)
from repro.runtime import ProblemSpec

m, k = 16, 12           # 16 workers, wait for the fastest 12

# 1. the ORIGINAL problem every strategy solves (ridge, lam = 0.05)
ps = ProblemSpec.synthetic(n=512, p=128, noise=0.5, lam=0.05, seed=0)

# 2. one declarative spec: that problem + encoded gradient descent on a
#    simulated cluster with bimodal delays (paper §5.3, barrier accounting)
spec = ExperimentSpec(
    problems=(ProblemAxis.from_spec(ps),),
    strategies=(StrategyAxis("coded-gd", encoder="hadamard", k=k),),
    delays=DelayAxis.of("bimodal", m=m),
    steps=200)

# 3. plan + execute; the single cell's outcome carries both the JSON-ready
#    record and the raw RunResult (trace, final iterate), oblivious to the
#    erasures
res = run(spec).outcomes[0].result

# 4. compare against the exact ridge solution
w_star = ps.w_star()
prob = make_encoded_problem(ps.X, ps.y, identity_encoder(ps.n), m,
                            lam=ps.lam)
f_star = float(original_objective(prob, jnp.asarray(w_star), h="l2"))
f0 = float(original_objective(prob, jnp.zeros(ps.p), h="l2"))
print(f"f(w_0)   = {f0:.4f}")
print(f"f(w_1)   = {res.objective[0]:.4f}   (trace[t] = f after update t+1)")
print(f"f(w_T)   = {res.final_objective:.4f}   "
      f"(encoded, {m - k} stragglers/step)")
print(f"f(w*)    = {f_star:.4f}   (exact optimum)")
print(f"suboptimality: {res.final_objective / f_star - 1:.2%}")
print(f"simulated wall-clock: {res.wallclock:.1f}s for {len(res.objective)} "
      f"iterations")
assert res.final_objective < 1.05 * f_star
print("OK: converged within the paper's kappa-ball of the optimum")
