"""Quickstart: encoded distributed ridge regression via the cluster runtime.

The master waits for the fastest k of m workers every iteration; the
Hadamard encoding makes the fastest-k gradient a faithful estimate of the
full gradient regardless of WHICH workers straggle.  The runtime engine
simulates the cluster (bimodal delays from the paper) and the whole
iteration loop runs as one device-resident `lax.scan`.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import bimodal_delays, identity_encoder, \
    make_encoded_problem, original_objective
from repro.runtime import ClusterEngine, ProblemSpec, get_strategy

m, k = 16, 12           # 16 workers, wait for the fastest 12

# 1. the ORIGINAL problem every strategy solves (ridge, lam = 0.05)
spec = ProblemSpec.synthetic(n=512, p=128, noise=0.5, lam=0.05, seed=0)

# 2. a simulated cluster: bimodal delays (paper §5.3), barrier accounting
engine = ClusterEngine(bimodal_delays(), m, seed=0)

# 3. run encoded gradient descent, oblivious to the erasures
res = get_strategy("coded-gd").run(spec, engine, steps=200, k=k,
                                   encoder="hadamard")

# 4. compare against the exact ridge solution
w_star = spec.w_star()
prob = make_encoded_problem(spec.X, spec.y, identity_encoder(spec.n), m,
                            lam=spec.lam)
f_star = float(original_objective(prob, jnp.asarray(w_star), h="l2"))
f0 = float(original_objective(prob, jnp.zeros(spec.p), h="l2"))
print(f"f(w_0)   = {f0:.4f}")
print(f"f(w_1)   = {res.objective[0]:.4f}   (trace[t] = f after update t+1)")
print(f"f(w_T)   = {res.final_objective:.4f}   "
      f"(encoded, {m - k} stragglers/step)")
print(f"f(w*)    = {f_star:.4f}   (exact optimum)")
print(f"suboptimality: {res.final_objective / f_star - 1:.2%}")
print(f"simulated wall-clock: {res.wallclock:.1f}s for {len(res.objective)} "
      f"iterations")
assert res.final_objective < 1.05 * f_star
print("OK: converged within the paper's kappa-ball of the optimum")
